"""Closed-loop rate control (repro.dist.ratectl, DESIGN.md §3.6).

Controller-level properties (budget adherence, open-loop eq.-(8) limit,
water-fill invariants, monotone rates, staleness cap, jit-compatible
state) plus the trainer integration: ``auto:*`` policies end-to-end
through ``train_gnn`` with per-pair History columns, and the policy-level
guards.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CommPolicy
from repro.core.varco import AUTO_CONTROLLERS
from repro.dist.gnn_parallel import DistMeta, make_train_step
from repro.dist.ratectl import (CONTROLLERS, RatePlan, budget_controller,
                                error_controller, exchange_widths,
                                make_auto_train_step, make_controller,
                                make_pacing, stale_controller, uniform_plan,
                                waterfill)
from repro.graph import partition_graph, tiny_graph
from repro.nn import GNNConfig, init_gnn
from repro.train.optim import adamw

Q, F, T = 4, 512, 40


@pytest.fixture(scope="module")
def meta():
    g = tiny_graph(n=256, feat_dim=F)
    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=2)
    params = init_gnn(jax.random.key(0), cfg)
    pg = partition_graph(g, Q, scheme="random")
    return DistMeta.build(pg, params, wire="p2p"), cfg


def _simulate(ctl, meta_, widths, steps: int, floor_k: int = 1):
    """Drive a controller against the true quantised transport model
    (kept blocks floor at 1) and return the bits it ships."""
    rows = meta_.pair_table().astype(np.float64)
    nb = F // 128
    spent = 0.0
    state = ctl.init()
    for t in range(steps):
        plan, state = ctl.plan(state, t)
        r = np.asarray(plan.rates, np.float64)
        k = np.clip(np.floor(nb / np.maximum(r, 1.0)), floor_k, nb)
        np.fill_diagonal(k, 0.0)
        bits = 2.0 * 32.0 * len(widths) * float((rows * k * 128).sum())
        spent += bits
        state = ctl.observe(state, {
            "transport_bits": jnp.asarray(bits, jnp.float32),
            "pair_err": jnp.asarray(rows * (1.0 - k / nb), jnp.float32),
            "pair_delta": jnp.ones((Q, Q), jnp.float32)})
    return spent, state


# ---------------------------------------------------------------------------
# budget controller
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("frac", [0.3, 0.5, 0.8])
def test_budget_controller_lands_within_5pct(meta, frac):
    meta_, cfg = meta
    widths = exchange_widths(cfg)
    d_full = 2.0 * 32.0 * meta_.halo_demand * sum(widths)
    budget = frac * d_full * T
    ctl = budget_controller(Q, make_pacing(meta_, widths, T, budget))
    spent, _ = _simulate(ctl, meta_, widths, T)
    assert abs(spent - budget) / budget <= 0.05, (frac, spent / budget)


def test_budget_controller_open_loop_limit(meta):
    """Zero gains + the eq.-(8) schedule's own total as budget → the plan
    IS eq. (8): same linear anneal, clamped to [c_min, c_max]."""
    meta_, cfg = meta
    from repro.core import schedulers
    widths = exchange_widths(cfg)
    sched = schedulers.linear(T, slope=5.0)
    d_full = 2.0 * 32.0 * meta_.halo_demand * sum(widths)
    budget = d_full * float(sum(1.0 / float(sched(t)) for t in range(T)))
    ctl = budget_controller(
        Q, make_pacing(meta_, widths, T, budget, kp=0.0, ki=0.0))
    state = ctl.init()
    for t in range(T):
        plan, state = ctl.plan(state, t)
        off = np.asarray(plan.rates)[~np.eye(Q, dtype=bool)]
        np.testing.assert_allclose(off, float(sched(t)), rtol=1e-4)
        # feed back the un-quantised transport the plan implies — the
        # receding-horizon replanning then telescopes to eq. (8) exactly
        state = ctl.observe(state, {
            "transport_bits": jnp.asarray(d_full / off[0], jnp.float32),
            "pair_err": jnp.zeros((Q, Q)),
            "pair_delta": jnp.zeros((Q, Q))})


def test_uniform_plan_shape():
    p = uniform_plan(3, 7.0)
    assert isinstance(p, RatePlan)
    np.testing.assert_allclose(np.diag(np.asarray(p.rates)), 1.0)
    assert np.all(np.asarray(p.skip) == 0.0)


# ---------------------------------------------------------------------------
# error controller
# ---------------------------------------------------------------------------


def test_waterfill_invariants():
    rows = jnp.asarray([[0.0, 10.0], [5.0, 0.0]])
    density = jnp.asarray([[0.0, 4.0], [1.0, 0.0]])
    y = np.asarray(waterfill(density, rows, cap=jnp.asarray(7.5),
                             y_floor=0.25))
    # cap respected, floors respected, denser pair fills first
    assert float((np.asarray(rows) * y).sum()) <= 7.5 + 1e-4
    assert np.all(y >= 0.25 - 1e-6)
    assert y[0, 1] >= y[1, 0]
    # equal densities degrade to the uniform allocation
    y_eq = np.asarray(waterfill(jnp.ones((2, 2)), jnp.ones((2, 2)),
                                cap=jnp.asarray(2.0), y_floor=0.1))
    np.testing.assert_allclose(y_eq, 0.5, rtol=1e-4)
    # a floor already above cap is returned unchanged (commitments win)
    y_fl = np.asarray(waterfill(density, rows, cap=jnp.asarray(1.0),
                                y_floor=0.5))
    np.testing.assert_allclose(y_fl, 0.5, rtol=1e-6)


def test_error_controller_rates_monotone_and_budgeted(meta):
    meta_, cfg = meta
    widths = exchange_widths(cfg)
    d_full = 2.0 * 32.0 * meta_.halo_demand * sum(widths)
    budget = 0.5 * d_full * T
    ctl = error_controller(Q, make_pacing(meta_, widths, T, budget),
                           meta_.pair_table())
    rows = meta_.pair_table().astype(np.float64)
    nb = F // 128
    state = ctl.init()
    prev = None
    spent = 0.0
    off = (rows > 0)
    for t in range(T):
        plan, state = ctl.plan(state, t)
        r = np.asarray(plan.rates, np.float64)
        if prev is not None:   # per-pair rates never increase (Prop. 2)
            assert np.all(r[off] <= prev[off] + 1e-5)
        prev = r
        k = np.clip(np.floor(nb / np.maximum(r, 1.0)), 1, nb)
        np.fill_diagonal(k, 0.0)
        bits = 2.0 * 32.0 * len(widths) * float((rows * k * 128).sum())
        spent += bits
        err = rows * (1.0 - k / nb) * (1.0 + (np.arange(Q * Q) % 3)
                                       .reshape(Q, Q))
        state = ctl.observe(state, {
            "transport_bits": jnp.asarray(bits, jnp.float32),
            "pair_err": jnp.asarray(err, jnp.float32),
            "pair_delta": jnp.zeros((Q, Q), jnp.float32)})
    assert spent <= 1.1 * budget, spent / budget


# ---------------------------------------------------------------------------
# stale controller
# ---------------------------------------------------------------------------


def test_stale_skip_threshold_and_cap(meta):
    meta_, cfg = meta
    widths = exchange_widths(cfg)
    cap = 3
    ctl = stale_controller(Q, make_pacing(meta_, widths, T, 1e9),
                           threshold=0.1, max_stale=cap)
    state = ctl.init()
    plan, state = ctl.plan(state, 0)
    assert np.all(np.asarray(plan.skip) == 0.0)       # never skip blind
    # unchanged pairs get skipped... but only max_stale times in a row
    consecutive = 0
    for t in range(1, 10):
        state = ctl.observe(state, {
            "transport_bits": jnp.zeros(()),
            "pair_err": jnp.zeros((Q, Q)),
            "pair_delta": jnp.zeros((Q, Q))})          # nothing changed
        plan, state = ctl.plan(state, t)
        sk = np.asarray(plan.skip)
        assert np.all(np.diag(sk) == 0.0)
        if sk[0, 1] > 0:
            consecutive += 1
            assert consecutive <= cap
        else:
            assert consecutive == cap                  # forced refresh
            consecutive = 0
    # a large delta forces a refresh immediately
    state = ctl.observe(state, {
        "transport_bits": jnp.zeros(()),
        "pair_err": jnp.zeros((Q, Q)),
        "pair_delta": jnp.ones((Q, Q))})
    plan, _ = ctl.plan(state, 10)
    assert np.all(np.asarray(plan.skip) == 0.0)


# ---------------------------------------------------------------------------
# API: jit-compatibility, dispatch, guards
# ---------------------------------------------------------------------------


def test_controller_state_is_jit_compatible(meta):
    meta_, cfg = meta
    widths = exchange_widths(cfg)
    for factory in (lambda: budget_controller(
                        Q, make_pacing(meta_, widths, T, 1e9)),
                    lambda: error_controller(
                        Q, make_pacing(meta_, widths, T, 1e9),
                        meta_.pair_table()),
                    lambda: stale_controller(
                        Q, make_pacing(meta_, widths, T, 1e9))):
        ctl = factory()
        state = ctl.init()
        plan, state = jax.jit(ctl.plan)(state, jnp.asarray(3))
        obs = {"transport_bits": jnp.ones(()),
               "pair_err": jnp.ones((Q, Q)),
               "pair_delta": jnp.zeros((Q, Q))}
        state = jax.jit(ctl.observe)(state, obs)
        assert plan.rates.shape == (Q, Q)


def test_make_controller_dispatch_and_registry(meta):
    meta_, cfg = meta
    assert CONTROLLERS == AUTO_CONTROLLERS
    for name in CONTROLLERS:
        pol = CommPolicy.parse(f"auto:{name}:1e9", T)
        ctl = make_controller(pol, meta_, cfg, T)
        assert ctl.name == name
    with pytest.raises(ValueError, match="auto"):
        make_controller(CommPolicy("full"), meta_, cfg, T)


def test_auto_policy_guards(meta):
    meta_, cfg = meta
    pol = CommPolicy.parse("auto:budget:1e9", T)
    with pytest.raises(ValueError, match="ratectl"):
        pol.rate(0)
    with pytest.raises(ValueError, match="ratectl"):
        make_train_step(cfg, pol, adamw(1e-3), meta_)
    import dataclasses
    dense = dataclasses.replace(meta_, wire="dense")
    with pytest.raises(ValueError, match="packed|p2p"):
        make_auto_train_step(cfg, pol, adamw(1e-3), dense)
    stale_pol = CommPolicy.parse("auto:stale:1e9", T)
    packed = dataclasses.replace(meta_, wire="packed")
    with pytest.raises(ValueError, match="p2p"):
        make_auto_train_step(cfg, stale_pol, adamw(1e-3), packed)


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ctl", ["budget", "stale"])
def test_train_gnn_auto_end_to_end(ctl):
    from repro.train import train_gnn

    g = tiny_graph(n=256, feat_dim=F)
    epochs = 8
    budget = 0.5 * 8e6 * epochs
    pol = CommPolicy.parse(f"auto:{ctl}:{budget:g}", epochs)
    res = train_gnn(g, q=Q, scheme="random", policy=pol, epochs=epochs,
                    hidden=F, layers=2, eval_every=4)
    h = res.history
    assert len(h.pair_transport_gf) == len(h.epoch) > 0
    # per-pair columns decompose the cumulative transport
    np.testing.assert_allclose(sum(h.pair_transport_gf[-1]),
                               h.transport_gfloats[-1], rtol=1e-5)
    assert "pair_transport_gf" in h.row(0)
    assert res.meta.wire == "p2p"        # auto defaults the wire to p2p
    assert np.isfinite(h.final_test_acc)


def test_stale_step_reuses_cache_and_charges_nothing(meta):
    """A forced all-skip step delivers the cached hops and ships zero
    bits; the forced all-refresh step matches a fresh run bitwise."""
    meta_, cfg = meta
    g = tiny_graph(n=256, feat_dim=F)
    pg = partition_graph(g, Q, scheme="random")
    from repro.dist.halo import attach_p2p
    from repro.dist.ratectl import init_halo_cache
    graph = attach_p2p(pg.device_arrays(), pg)
    params = init_gnn(jax.random.key(0), cfg)
    meta_p = DistMeta.build(pg, params, wire="p2p")
    pol = CommPolicy.parse("auto:stale:1e9", T)
    opt = adamw(5e-3)
    step = make_auto_train_step(cfg, pol, opt, meta_p)
    cache = init_halo_cache(meta_p, cfg)
    eye = np.eye(Q, dtype=bool)
    rm = jnp.where(jnp.asarray(eye), 1.0, 2.0)
    no_skip = RatePlan(rm, jnp.zeros((Q, Q)))
    all_skip = RatePlan(rm, jnp.asarray(~eye, jnp.float32))

    p0, s0 = params, opt.init(params)
    p1, s1, m1, cache1 = step(p0, s0, graph, jax.random.key(1), no_skip,
                              cache)
    assert float(m1["transport_bits"]) > 0.0
    # skip everything: zero transport, and the delivered halos are the
    # cached ones → same params as re-running with the cache as truth
    p2, s2, m2, cache2 = step(p1, s1, graph, jax.random.key(2), all_skip,
                              cache1)
    assert float(m2["transport_bits"]) == 0.0
    assert float(np.asarray(m2["pair_delta"]).max()) >= 0.0
    for a, b in zip(cache1, cache2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
