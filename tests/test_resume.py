"""Crash-consistent resume (ISSUE 8): interrupting any golden-trace
config mid-run and restoring from the checkpoint must continue
**bitwise** on the uninterrupted trajectory.

Each config trains once uninterrupted (eval every epoch), then is
interrupted at steps {7, 19} via ``stop_after`` (checkpoint + exit) and
resumed; the resumed losses are pinned exactly — not allclose — against
the uninterrupted tail.  Covers all golden policies including the
quantised-wire ``auto:budget:…:w8`` (error-feedback residuals ride the
checkpoint) plus ``auto:stale`` (hop caches + skip state do too), and
the uninterrupted curves are cross-checked against
``tests/golden_traces.json`` where a golden exists."""

import json
import os

import numpy as np
import pytest

from test_golden_trace import (EPOCHS, EVAL_EVERY, FEAT, GOLDEN_PATH, HIDDEN,
                               LAYERS, N, QW, SEED, _budget_bits, _policies)

INTERRUPTS = (7, 19)

_uninterrupted: dict = {}


def _specs() -> dict:
    specs = dict(_policies())
    specs["auto_stale"] = f"auto:stale:{_budget_bits():g}"
    return specs


def _train(spec: str, **kw):
    from repro.core import CommPolicy
    from repro.graph import tiny_graph
    from repro.train.trainer import train_gnn

    g = tiny_graph(n=N, feat_dim=FEAT)
    policy = CommPolicy.parse(spec, EPOCHS, compressor="blockmask")
    return train_gnn(g, q=QW, scheme="random", policy=policy,
                     epochs=EPOCHS, hidden=HIDDEN, layers=LAYERS,
                     seed=SEED, eval_every=1, wire="p2p", **kw)


def _full_run(name: str, spec: str):
    if name not in _uninterrupted:
        _uninterrupted[name] = _train(spec)
    return _uninterrupted[name]


@pytest.mark.parametrize("name", sorted(_specs()))
@pytest.mark.parametrize("k", INTERRUPTS)
def test_resume_is_bitwise(name, k, tmp_path):
    spec = _specs()[name]
    full = _full_run(name, spec)
    ck = os.path.join(tmp_path, "ck")
    partial = _train(spec, checkpoint_dir=ck, stop_after=k)
    assert len(partial.history.loss) == k, "stop_after must halt the run"
    resumed = _train(spec, checkpoint_dir=ck, resume=True)
    assert resumed.history.loss == full.history.loss[k:], \
        f"{name} interrupted at {k}: resumed tail diverged"
    # the cumulative ledger resumes too (counters ride the checkpoint)
    assert resumed.history.transport_gfloats[-1] == \
        full.history.transport_gfloats[-1]
    assert resumed.history.halo_gfloats[-1] == \
        full.history.halo_gfloats[-1]
    if full.history.pair_transport_gf:
        assert resumed.history.pair_transport_gf[-1] == \
            full.history.pair_transport_gf[-1]


@pytest.mark.parametrize("name", sorted(_policies()))
def test_uninterrupted_run_stays_on_golden(name):
    """The eval-every-epoch runs the resume tests pin against still sit
    on the committed golden curves (sampled at the golden cadence)."""
    if os.environ.get("GOLDEN_REGEN"):
        pytest.skip("golden refresh handled by test_golden_trace")
    assert os.path.exists(GOLDEN_PATH), \
        "golden_traces.json missing — run with GOLDEN_REGEN=1"
    with open(GOLDEN_PATH) as fh:
        golden = json.load(fh)[name]
    full = _full_run(name, _policies()[name])
    idx = list(range(0, EPOCHS, EVAL_EVERY))
    if (EPOCHS - 1) not in idx:
        idx.append(EPOCHS - 1)
    sampled = [full.history.loss[i] for i in idx]
    np.testing.assert_allclose(np.asarray(sampled),
                               np.asarray(golden["loss"]), rtol=1e-4,
                               atol=1e-6)


def test_resume_requires_checkpoint(tmp_path):
    spec = _specs()["full"]
    with pytest.raises(FileNotFoundError):
        _train(spec, checkpoint_dir=os.path.join(tmp_path, "none"),
               resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _train(spec, resume=True)
