"""Scheduler properties (paper Prop. 2 requires strict decrease)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedulers
from repro.core.varco import CommPolicy


@pytest.mark.parametrize("sched", [
    schedulers.linear(100, slope=5),
    schedulers.linear(100, slope=2),
    schedulers.fixed_step(100, decrement=1.5),
    schedulers.exponential(100),
    schedulers.cosine(100),
])
def test_monotone_nonincreasing_and_clamped(sched):
    ts = jnp.arange(0, 130)
    cs = np.asarray(jnp.stack([sched(t) for t in ts]))
    assert np.all(np.diff(cs) <= 1e-6)
    assert cs.min() >= sched.c_min - 1e-6
    assert cs.max() <= sched.c_max + 1e-6
    # strictly decreasing until the floor (Prop. 2's condition)
    before_floor = cs > sched.c_min + 1e-6
    if before_floor.sum() > 2:
        seg = cs[before_floor]
        assert np.all(np.diff(seg) < 0)


def test_linear_matches_paper_eq8():
    """c(t) = clamp(c_max - a (c_max - c_min) t / T, c_min, c_max)."""
    T, a = 300, 5.0
    s = schedulers.linear(T, slope=a)
    for t in [0, 10, 30, 59, 60, 200]:
        expect = np.clip(128.0 - a * 127.0 * t / T, 1.0, 128.0)
        assert abs(float(s(t)) - expect) < 1e-4


def test_call_clamps_to_ceiling_and_floor():
    """Regression: a mis-specified fn can never escape [c_min, c_max] —
    the ceiling clamp used to be missing (only the floor was applied)."""
    wild = schedulers.Scheduler(
        "wild", lambda t: jnp.where(t < 1.0, 1e6, -1e6), c_max=128.0,
        c_min=1.0)
    assert float(wild(0)) == 128.0        # above ceiling → clamped down
    assert float(wild(5)) == 1.0          # below floor → clamped up


def test_parse_specs():
    assert schedulers.parse("fixed:4", 10).name == "fixed:4"
    assert schedulers.parse("linear:3", 10).name == "linear:a=3"
    assert schedulers.parse("exp", 10).name == "exp"
    with pytest.raises(ValueError):
        schedulers.parse("bogus", 10)


def test_policy_parse_and_rates():
    p = CommPolicy.parse("varco:linear:5", 300)
    assert p.mode == "varco" and p.compresses
    assert float(p.rate(0)) == 128.0
    assert float(p.rate(300)) == 1.0
    full = CommPolicy.parse("full", 300)
    assert not full.compresses and float(full.rate(0)) == 1.0
    none = CommPolicy.parse("none", 300)
    assert not none.communicates
    fixed = CommPolicy.parse("fixed:4", 300)
    assert float(fixed.rate(123)) == 4.0


# ---------------------------------------------------------------------------
# CommPolicy.parse round trips: every documented spec string
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec,mode,desc_frag", [
    ("full", "full", "full"),
    ("none", "none", "none"),
    ("fixed:4", "fixed", "fixed:4"),
    ("varco:linear:5", "varco", "linear:a=5"),
    ("varco:exp", "varco", "exp"),
    ("varco:cosine", "varco", "cosine"),
    ("varco:step:0.5", "varco", "step:R=0.5"),
    ("auto:budget:2e+09", "auto", "budget"),
    ("auto:error:2e+09", "auto", "error"),
    ("auto:stale:2e+09", "auto", "stale"),
    ("auto:budget:2e+09:w8", "auto", "budget"),
    ("auto:budget:2e+09:w2", "auto", "budget"),
    ("auto:error:2e+09:w4", "auto", "error"),
    ("auto:stale:2e+09:w8", "auto", "stale"),
    ("auto:budget:2e+09:per-layer", "auto", "budget"),
    ("auto:error:2e+09:w4:per-layer", "auto", "error"),
])
def test_policy_parse_round_trip(spec, mode, desc_frag):
    p = CommPolicy.parse(spec, 300)
    assert p.mode == mode
    assert desc_frag in p.describe()
    # every documented spec string is its own canonical form
    assert str(p) == spec
    if mode == "auto":
        assert p.budget_bits == 2e9
        assert p.compressor_name == "blockmask"   # auto forces the wire's
        assert p.compresses and p.communicates    # lane-block compressor
        want_w = 32
        for part in spec.split(":"):
            if part and part[0] == "w" and part[1:].isdigit():
                want_w = int(part[1:])
        assert p.max_width == want_w
    if mode in ("fixed", "varco"):
        assert p.scheduler is not None
        assert p.max_width == 32


def test_policy_width_suffix_order_insensitive():
    """`:w<bits>` and `:per-layer` compose in either order; __str__
    canonicalises to width-first."""
    a = CommPolicy.parse("auto:budget:2e+09:w4:per-layer", 300)
    b = CommPolicy.parse("auto:budget:2e+09:per-layer:w4", 300)
    assert a.max_width == b.max_width == 4
    assert a.per_layer and b.per_layer
    assert str(a) == str(b) == "auto:budget:2e+09:w4:per-layer"


@pytest.mark.parametrize("bad", [
    "bogus",                 # unknown mode
    "auto",                  # missing controller + budget
    "auto:budget",           # missing budget
    "auto:budget:",          # empty budget
    "auto:bogus:2e9",        # unknown controller
    "auto:budget:xyz",       # non-numeric budget
    "auto:budget:-5",        # non-positive budget
    "fixed:abc",             # non-numeric rate
    "auto:budget:2e9:w0",    # zero-bit wire
    "auto:budget:2e9:w3",    # not a supported width
    "auto:budget:2e9:w64",   # wider than fp32
    "auto:budget:2e9:w",     # empty width
    "auto:budget:2e9:bogus",  # unknown suffix
])
def test_policy_parse_malformed(bad):
    with pytest.raises(ValueError):
        CommPolicy.parse(bad, 300)


def test_auto_policy_requires_blockmask():
    with pytest.raises(ValueError, match="blockmask"):
        CommPolicy.parse("auto:budget:1e9", 300, compressor="randmask")


def test_width_floor_needs_auto_mode():
    """Sub-32 wires are controller-driven (the rate × width allocation);
    open-loop policies must reject the field even when constructed
    directly, not just through parse."""
    with pytest.raises(ValueError, match="auto"):
        CommPolicy("full", max_width=8)
    with pytest.raises(ValueError):
        CommPolicy("auto", controller="budget", budget_bits=1e9,
                   max_width=5)          # not in WIRE_WIDTHS either
