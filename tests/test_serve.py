"""Serving subsystem tests (repro.serve, DESIGN.md §3.11).

Covers the §3.11 acceptance surface: the shared drift predicate (one
function, two call sites — training hop reuse and serving cache
invalidation), cold-start vs warm-cache wire-bit ledgers, FRESH
exactness, streaming-update incremental recompute, the micro-batching
frontend, the ``qos`` controller, and the launcher CLI fix.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

F = 128
N = 192
Q = 4
LAYERS = 2


@pytest.fixture(scope="module")
def setup():
    from repro.graph.synthetic import citation_graph
    from repro.nn import GNNConfig, init_gnn

    g = citation_graph(n=N, feat_dim=F, seed=0)
    cfg = GNNConfig(conv="sage", in_dim=F, hidden=F,
                    out_dim=g.num_classes, layers=LAYERS)
    params = init_gnn(jax.random.key(0), cfg)
    return g, cfg, params


@pytest.fixture()
def engine(setup):
    from repro.serve import ServingEngine

    g, cfg, params = setup
    return ServingEngine(g, params, cfg, q=Q, seed=0)


# ---------------------------------------------------------------------------
# S4: the shared drift predicate
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000), q=st.integers(2, 6),
       threshold=st.floats(0.0, 0.5), max_stale=st.integers(1, 6))
@settings(max_examples=25)
def test_drift_predicate_shared(seed, q, threshold, max_stale):
    """Serving invalidation fires EXACTLY when training hop reuse would
    stop skipping: ``EmbeddingCache.plan_refresh`` and the ``stale``
    controller's ``observe`` must produce identical masks from identical
    drift measurements (both are ``drift_skip``)."""
    from repro.dist.ratectl.stale import drift_skip, stale_controller
    from repro.serve.cache import EmbeddingCache

    rng = np.random.default_rng(seed)
    delta = rng.uniform(0.0, 1.0, (q, q)).astype(np.float32)
    prev_skip = (rng.uniform(size=(q, q)) < 0.5).astype(np.float32)
    age0 = rng.integers(0, max_stale + 2, (q, q)).astype(np.float32)

    # the training side: observe folds the drift into the skip mask
    # (pacing is plan-only state, never touched by observe)
    ctl = stale_controller(q, None, threshold=threshold,
                          max_stale=max_stale)
    state = {"spent": jnp.zeros(()), "integ": jnp.zeros(()),
             "age": jnp.asarray(age0), "skip": jnp.asarray(prev_skip)}
    out = ctl.observe(state, {"pair_delta": delta,
                              "transport_bits": 0.0})

    # the serving side: same age bookkeeping, same predicate
    age = np.where(prev_skip > 0.0, age0 + 1.0, 0.0)
    serve_mask = np.asarray(EmbeddingCache.plan_refresh(
        delta, age, threshold, max_stale))

    np.testing.assert_array_equal(serve_mask, np.asarray(out["skip"]))
    np.testing.assert_array_equal(
        serve_mask, np.asarray(drift_skip(delta, age, threshold,
                                          max_stale)))
    assert not np.any(np.diagonal(serve_mask))


def test_drift_skip_semantics():
    from repro.dist.ratectl.stale import drift_skip

    delta = np.array([[0.0, 0.01], [0.9, 0.0]], np.float32)
    age = np.zeros((2, 2), np.float32)
    skip = np.asarray(drift_skip(delta, age, 0.05, 3))
    assert skip[0, 1] == 1.0 and skip[1, 0] == 0.0   # drift gate
    age[0, 1] = 3.0
    skip = np.asarray(drift_skip(delta, age, 0.05, 3))
    assert skip[0, 1] == 0.0                          # staleness cap


# ---------------------------------------------------------------------------
# tentpole: serving engine
# ---------------------------------------------------------------------------


def test_fresh_serving_matches_centralized(setup, engine):
    from repro.nn.gnn import centralized_forward

    g, cfg, params = setup
    engine.refresh(force=True)
    emb, status = engine.serve(np.arange(N))
    assert status == "FRESH"
    ref = np.asarray(centralized_forward(params, cfg, g))
    assert np.max(np.abs(emb - ref)) <= 1e-5


def test_cold_vs_warm_wire_bit_ledger(engine):
    """S4: cold start pays the full exact halo refresh; once drift
    gating engages, a warm refresh charges strictly fewer wire bits —
    and a fully-gated refresh charges zero."""
    m_cold = engine.refresh(force=True)
    cold = float(m_cold["transport_bits"])
    assert cold > 0.0
    m_warm = engine.refresh()            # compressed rate x width refresh
    warm = float(m_warm["transport_bits"])
    assert warm < cold
    # drift measured ~0 under the fixed refresh key -> everything gated
    m_gated = engine.refresh()
    assert float(m_gated["transport_bits"]) == 0.0
    # the ledger saw all three charges
    assert float(engine.ledger.transport) == pytest.approx(cold + warm,
                                                           rel=1e-6)


def test_fresh_survives_fully_gated_refresh(engine):
    engine.refresh(force=True)
    assert engine.status() == "FRESH"
    # a second exact refresh measures zero drift against the exact halo
    # cache, priming the gate; the next gated refresh then recomputes
    # from identical halos at zero wire bits -- exactness survives it
    engine.refresh(force=True)
    m = engine.refresh()
    assert float(m["transport_bits"]) == 0.0
    assert engine.status() == "FRESH"
    # ...until a pair actually refreshes through the compressed wire
    engine._skip_next = np.zeros_like(np.asarray(engine._skip_next))
    engine.refresh()
    assert engine.status() == "CACHED"


def test_query_mass_reaches_controller(engine):
    engine.refresh(force=True)
    engine.serve(np.arange(64))
    qc = engine.query_counts()
    assert qc.sum() == 64
    mass0 = np.asarray(engine._ctl_state["mass"]).copy()
    engine.refresh()
    assert engine.query_counts().sum() == 0          # folded + reset
    assert not np.allclose(np.asarray(engine._ctl_state["mass"]), mass0)


def test_incremental_update_matches_full(setup, engine):
    from repro.nn.gnn import centralized_forward

    g, cfg, params = setup
    engine.refresh(force=True)
    rng = np.random.default_rng(3)
    dst0, src0 = g.edge_list()
    pick = rng.integers(0, len(dst0), 5)
    touched, fronts = engine.apply_updates(
        inserts=(rng.integers(0, N, 6), rng.integers(0, N, 6)),
        deletes=(dst0[pick], src0[pick]))
    assert len(fronts) == LAYERS
    assert len(fronts[0]) <= len(fronts[1])          # frontier grows
    ref = np.asarray(centralized_forward(params, cfg, engine.g))
    emb, status = engine.serve(np.arange(N))
    assert status == "CACHED"
    assert np.max(np.abs(emb - ref)) <= 1e-5


def test_apply_edge_updates_netting(setup):
    from repro.serve import apply_edge_updates

    g, _, _ = setup
    dst0, src0 = g.edge_list()
    # inserting a present edge and deleting an absent one are no-ops
    absent = None
    es = set(zip(dst0.tolist(), src0.tolist()))
    for u in range(N):
        for v in range(u + 1, N):
            if (u, v) not in es:
                absent = (u, v)
                break
        if absent:
            break
    g2, touched = apply_edge_updates(
        g, inserts=([dst0[0]], [src0[0]]),
        deletes=([absent[0]], [absent[1]]))
    assert g2.num_edges == g.num_edges
    np.testing.assert_array_equal(g2.indptr, g.indptr)
    np.testing.assert_array_equal(g2.indices, g.indices)
    assert set(touched) == {dst0[0], src0[0], absent[0], absent[1]}
    # a real delete removes both directions
    g3, _ = apply_edge_updates(g, deletes=([dst0[0]], [src0[0]]))
    assert g3.num_edges == g.num_edges - 2
    g3.validate()


def test_edgespill_drop_nonpositive(tmp_path):
    from repro.graph.stream import EdgeSpill

    sp = EdgeSpill(16, str(tmp_path / "sp"), bucket_nodes=4,
                   weighted=True, drop_nonpositive=True)
    sp.add([1, 2, 3], [2, 1, 4], [1.0, 1.0, 1.0])
    sp.add([1, 2], [2, 1], [-1.0, -1.0])             # nets (1,2) out
    dst, src, w = sp.canonical_edges()
    assert list(zip(dst.tolist(), src.tolist())) == [(3, 4)]
    assert w.tolist() == [1.0]
    with pytest.raises(ValueError):
        EdgeSpill(16, str(tmp_path / "sp2"), drop_nonpositive=True)


# ---------------------------------------------------------------------------
# frontend micro-batching
# ---------------------------------------------------------------------------


def test_microbatcher_deadline_and_fill():
    from repro.serve import MicroBatcher

    owner = np.array([0, 0, 1, 1], np.int64)
    mb = MicroBatcher(owner, window_s=0.010, max_batch=2)
    assert not mb.ready(now=0.0)
    mb.submit(0, "a", now=0.0)
    assert not mb.ready(now=0.005)       # window not yet elapsed
    assert mb.ready(now=0.011)           # deadline trips
    mb.submit(2, "b", now=0.005)
    mb.submit((3,), "b", now=0.006)
    assert mb.ready(now=0.006)           # partition 1 batch full
    per_part = mb.drain()
    assert sorted(per_part) == [0, 1]
    assert [q.tenant for q in per_part[1]] == ["b", "b"]
    assert mb.pending == 0 and not mb.ready(now=1.0)
    with pytest.raises(ValueError):
        mb.submit((1, 2, 3))


def test_microbatcher_skewed_clock_tracks_true_minimum():
    # a submit stamped EARLIER than the queue's oldest (replayed /
    # skewed tenant clocks) must pull the deadline back; the old code
    # kept the first arrival and fired late or never
    from repro.serve import MicroBatcher

    owner = np.array([0, 0, 1, 1], np.int64)
    mb = MicroBatcher(owner, window_s=0.010, max_batch=8)
    mb.submit(0, "a", now=5.000)
    mb.submit(2, "b", now=4.995)         # earlier stamp, later submit
    assert mb._oldest == 4.995
    assert mb.ready(now=5.006)           # window past the TRUE oldest
    mb.drain()
    # drain resets the minimum; a fresh queue starts over
    mb.submit(1, "a", now=7.0)
    assert mb._oldest == 7.0
    assert not mb.ready(now=7.005)


def test_engine_flush_matches_direct_serve(engine):
    engine.refresh(force=True)
    engine.submit(3, "a", now=0.0)
    engine.submit((5, 7), "b", now=0.0)              # edge query
    assert engine.flush(now=0.0) == []               # window still open
    out = engine.flush(now=1.0)
    assert [qy.nodes for qy, _ in out] in ([(3,), (5, 7)],
                                           [(5, 7), (3,)])
    direct3, _ = engine.serve([3])
    edge57, _ = engine.serve_edges([(5, 7)])
    got = {qy.nodes: emb for qy, emb in out}
    np.testing.assert_allclose(got[(3,)], direct3[0])
    np.testing.assert_allclose(got[(5, 7)], edge57[0])
    assert got[(5, 7)].shape == (2 * direct3.shape[1],)


# ---------------------------------------------------------------------------
# qos controller
# ---------------------------------------------------------------------------


def test_qos_policy_parse_roundtrip():
    from repro.core.varco import CommPolicy

    pol = CommPolicy.parse("auto:qos:2e9:w8", 10)
    assert pol.controller == "qos" and pol.max_width == 8
    assert CommPolicy.parse(str(pol), 10) == pol


def test_qos_in_controller_registries():
    from repro.core.varco import AUTO_CONTROLLERS
    from repro.dist.ratectl import CONTROLLERS

    assert tuple(AUTO_CONTROLLERS) == tuple(CONTROLLERS)
    assert "qos" in CONTROLLERS


def test_qos_controller_mass_weighted_waterfill(setup):
    from parity import build_setup
    from repro.core.varco import CommPolicy
    from repro.dist.gnn_parallel import DistMeta
    from repro.dist.ratectl import make_controller

    g, cfg, params, pg, graph = build_setup(Q, f=F, layers=LAYERS, n=N,
                                            hidden=F)
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:qos:1e8", 8)
    rows = np.asarray(meta.pair_table(), np.float32)
    # twin controllers at identical pacing state: only the query-mass
    # EMA differs, so the plans isolate the density operand
    ctl_a = make_controller(policy, meta, cfg, 8, ema_decay=0.5)
    ctl_b = make_controller(policy, meta, cfg, 8, ema_decay=0.5)
    state_a, state_b = ctl_a.init(), ctl_b.init()
    mass = np.zeros((Q, Q), np.float32)
    mass[0] = rows[0] * 1e3              # all traffic lands on part 0
    for _ in range(6):
        state_b = ctl_b.observe(state_b, {"transport_bits": 0.0,
                                          "query_mass": mass})
    plan_a, _ = ctl_a.plan(state_a, 0)   # uniform halo-row prior
    plan_b, _ = ctl_b.plan(state_b, 0)   # skewed query mass
    rates_a, rates_b = np.asarray(plan_a.rates), np.asarray(plan_b.rates)
    for r in (rates_a, rates_b):
        assert r.shape == (Q, Q)
        assert np.all(np.diagonal(r) == 1.0) and np.all(r >= 1.0)
    live0 = rows[0] > 0
    starved = (rows > 0) & (np.arange(Q)[:, None] != 0)
    # hot row refreshes at rates no higher, starved pairs no lower
    assert np.all(rates_b[0][live0] <= rates_a[0][live0] + 1e-6)
    assert np.all(rates_b[starved] >= rates_a[starved] - 1e-6)
    # and the skew actually moved something
    assert not np.allclose(rates_a, rates_b)
    # missing query_mass key leaves the EMA untouched
    mass_before = np.asarray(state_b["mass"]).copy()
    state_b = ctl_b.observe(state_b, {"transport_bits": 1.0})
    np.testing.assert_array_equal(np.asarray(state_b["mass"]),
                                  mass_before)
    # non-dict observations must fail with the contract, not a bare
    # TypeError from obs["transport_bits"] (the old isinstance guard
    # shielded only the query_mass lookup)
    for bad in (1.0, np.float32(3.0), [("transport_bits", 1.0)], None):
        with pytest.raises(TypeError, match="metrics dict"):
            ctl_b.observe(state_b, bad)


def test_qos_rejects_per_layer(setup):
    from parity import build_setup
    from repro.core.varco import CommPolicy
    from repro.dist.gnn_parallel import DistMeta
    from repro.dist.ratectl import make_controller

    g, cfg, params, pg, _ = build_setup(Q, f=F, layers=LAYERS, n=N,
                                        hidden=F)
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:qos:1e8:per-layer", 8)
    with pytest.raises(ValueError, match="per-layer qos"):
        make_controller(policy, meta, cfg, 8)
    # ema_decay stays rejected for the scalar budget controller
    with pytest.raises(ValueError, match="ema_decay"):
        make_controller(CommPolicy.parse("auto:budget:1e8", 8), meta,
                        cfg, 8, ema_decay=0.5)


def test_pair_query_mass():
    from repro.dist.halo import pair_query_mass

    rows = np.array([[0, 4], [2, 0]], np.float32)
    mass = pair_query_mass(rows, np.array([3.0, 5.0]))
    np.testing.assert_array_equal(mass, [[0.0, 12.0], [10.0, 0.0]])
    with pytest.raises(ValueError):
        pair_query_mass(rows, np.zeros(3))


# ---------------------------------------------------------------------------
# S1: launcher CLI
# ---------------------------------------------------------------------------


def test_serve_cli_smoke_flag_defaults_off():
    from repro.launch.serve import build_parser

    ap = build_parser()
    assert ap.parse_args([]).smoke is False
    assert ap.parse_args(["--smoke"]).smoke is True
    args = ap.parse_args(["--smoke", "--batch", "2"])
    assert args.smoke and args.batch == 2
