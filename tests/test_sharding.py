"""Parameter/cache sharding rules (divisibility, axis assignment)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import cache_spec, param_spec
from repro.launch.mesh import make_small_mesh


def abstract_mesh(shape, names):
    """Compat: jax >= 0.5 takes (sizes, names); 0.4.x takes (name, size) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


@pytest.fixture(scope="module")
def mesh():
    # 1 real device but mesh construction only needs shape arithmetic:
    # use (1, 1) sizes for rule tests that only exercise divisibility=no,
    # and a fake 16x16 via AbstractMesh for the real checks.
    return abstract_mesh((16, 16), ("data", "model"))


def test_attention_param_rules(mesh):
    assert param_spec("blocks/p0_attn/attn/wq", (9, 8192, 64, 128), mesh) \
        == P(None, ("data",), ("model",), None)
    assert param_spec("blocks/p0_attn/attn/wo", (9, 64, 128, 8192), mesh) \
        == P(None, ("model",), None, ("data",))
    # kv heads 8 don't divide model=16 -> replicated on that dim
    assert param_spec("blocks/p0_attn/attn/wk", (9, 8192, 8, 128), mesh) \
        == P(None, ("data",), None, None)


def test_mlp_and_embed_rules(mesh):
    assert param_spec("blocks/p0_attn/mlp/w_gate", (9, 4096, 11008), mesh) \
        == P(None, ("data",), ("model",))
    assert param_spec("blocks/p0_attn/mlp/w_down", (9, 11008, 4096), mesh) \
        == P(None, ("model",), ("data",))
    # vocab over model only (2D-sharded tables defeat GSPMD sparse lookup;
    # EXPERIMENTS.md §Perf it. 9)
    assert param_spec("embed", (64000, 4096), mesh) \
        == P(("model",), None)
    assert param_spec("lm_head", (4096, 64000), mesh) \
        == P(None, ("model",))


def test_moe_expert_parallel_rules(mesh):
    # expert parallelism lives on the DATA axis (single-axis MoE all-to-all,
    # EXPERIMENTS.md §Perf it. 3); expert ffn dim gets TP over model
    assert param_spec("blocks/p1_attn/moe/w_gate", (24, 128, 5120, 8192),
                      mesh) == P(None, ("data",), None, ("model",))
    assert param_spec("blocks/p1_attn/moe/w_down", (24, 128, 8192, 5120),
                      mesh) == P(None, ("data",), ("model",), None)
    # shared expert is a plain gated MLP
    assert param_spec("blocks/p1_attn/moe/shared/w_up", (24, 5120, 8192),
                      mesh) == P(None, ("data",), ("model",))


def test_norms_replicated(mesh):
    assert param_spec("blocks/p0_attn/norm1", (9, 8192), mesh) == P(None, None)
    assert param_spec("final_norm", (8192,), mesh) == P(None)


def test_cache_spec_kv_heads_vs_seq(mesh):
    # kv=8 can't shard over model=16 -> seq gets the model axis
    spec = cache_spec((40, 128, 32768, 8, 64), mesh, batch_dim=1, seq_dim=2,
                      head_dim=3)
    assert spec == P(None, ("data",), ("model",), None, None)
    # kv=16 divides -> heads sharded, seq left alone
    spec = cache_spec((40, 128, 32768, 16, 64), mesh, batch_dim=1, seq_dim=2,
                      head_dim=3)
    assert spec == P(None, ("data",), None, ("model",), None)
    # batch=1 long-context: seq takes data (and model if it still divides)
    spec = cache_spec((9, 1, 524288, 8, 128), mesh, batch_dim=1, seq_dim=2,
                      head_dim=3)
    assert spec == P(None, None, ("data", "model"), None, None)


def test_multipod_axes():
    mesh3 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert param_spec("embed", (65536, 8192), mesh3) \
        == P(("model",), None)
    assert param_spec("blocks/p0_mamba/mamba/in_proj", (9, 8192, 33536),
                      mesh3) == P(None, ("pod", "data"), ("model",))
