"""Out-of-core streaming pipeline unit tests (ISSUE 7 tentpole).

Covers the disk-backed graph store, the external-sort edge spill, the
streaming generators, the multilevel partitioner's quality bound, and
the per-worker shard loader — everything below the equivalence
properties in ``test_properties.py``.
"""

import os

import numpy as np
import pytest

from repro.graph import citation_graph, edge_cut_stats, tiny_graph
from repro.graph.partition import metis_like_partition
from repro.graph.stream import (EdgeSpill, load_graph_store, load_shards,
                                open_store, shard_meta, spill_to_store,
                                stream_edge_cut, stream_partition,
                                write_graph_store, write_shards)
from repro.graph.synthetic import stream_powerlaw_graph, stream_sbm_graph


# ---------------------------------------------------------------------------
# GraphStore round-trip
# ---------------------------------------------------------------------------


def test_store_manifest_and_degrees(tmp_path):
    g = tiny_graph(n=200, seed=3)
    store = write_graph_store(g, tmp_path / "s", chunk_nodes=37,
                              chunk_edges=251)
    assert store.num_nodes == g.num_nodes
    assert store.num_edges == g.num_edges
    assert store.feat_dim == g.feat_dim
    assert store.num_classes == g.num_classes
    # chunking never splits a row and tiles [0, n)
    rows = np.asarray(store.edge_rows)
    assert rows[0, 0] == 0 and rows[-1, 1] == g.num_nodes
    assert (rows[1:, 0] == rows[:-1, 1]).all()
    np.testing.assert_array_equal(store.degrees(), np.diff(g.indptr))
    # reopening from the manifest sees the same facts
    re = open_store(tmp_path / "s")
    assert re.num_edges == store.num_edges
    assert re.edge_rows == store.edge_rows


def test_store_roundtrip_bitwise(tmp_path):
    g = tiny_graph(n=150, feat_dim=9, seed=5)
    store = write_graph_store(g, tmp_path / "s", chunk_nodes=11,
                              chunk_edges=64)
    g2 = load_graph_store(store)
    for f in ("indptr", "indices", "features", "labels", "train_mask",
              "val_mask", "test_mask"):
        np.testing.assert_array_equal(getattr(g, f), getattr(g2, f),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# EdgeSpill external sort
# ---------------------------------------------------------------------------


def test_spill_canonicalises_like_from_edge_list(tmp_path):
    """Duplicates, self-loops, and arbitrary emit order all collapse to
    the same CSR that ``from_edge_list`` builds in memory."""
    n = 120
    rng = np.random.default_rng(7)
    dst = rng.integers(0, n, 800)
    src = rng.integers(0, n, 800)
    from repro.graph.data import from_edge_list
    ref = from_edge_list(n, dst, src, np.zeros((n, 4), np.float32),
                         np.zeros(n, np.int32))

    def emit(spill):
        # both directions, shuffled, in two awkward batches plus dups
        a = np.concatenate([dst, src, dst[:100]])
        b = np.concatenate([src, dst, src[:100]])
        p = rng.permutation(len(a))
        a, b = a[p], b[p]
        spill.add(a[:301], b[:301])
        spill.add(a[301:], b[301:])

    store = spill_to_store(n, emit, tmp_path / "s", name="t",
                           node_writer=None, feat_dim=0, num_classes=1,
                           chunk_nodes=17, chunk_edges=97)
    idx = np.concatenate([c[3] for c in store.edge_chunks()])
    np.testing.assert_array_equal(store.degrees(), np.diff(ref.indptr))
    np.testing.assert_array_equal(idx, ref.indices)


def test_spill_weighted_sums_duplicate_weights(tmp_path):
    n = 16
    sp = EdgeSpill(n, str(tmp_path / "w"), bucket_nodes=5, weighted=True)
    sp.add(np.array([1, 1, 2]), np.array([0, 0, 3]),
           np.array([1.5, 2.5, 1.0]))
    sp.add(np.array([1]), np.array([0]), np.array([0.25]))
    store = sp.to_store(tmp_path / "ws", name="w", node_writer=None,
                        feat_dim=0, num_classes=1, chunk_nodes=8,
                        chunk_edges=8)
    chunks = list(store.edge_chunks())
    idx = np.concatenate([c[3] for c in chunks])
    wgt = np.concatenate([c[4] for c in chunks])
    np.testing.assert_array_equal(idx, [0, 3])       # rows 1 and 2
    np.testing.assert_allclose(wgt, [4.25, 1.0])


# ---------------------------------------------------------------------------
# Streaming generators
# ---------------------------------------------------------------------------


def test_generators_invariant_to_io_chunking(tmp_path):
    """The emitted graph depends only on (n, seed, params) — never on
    the disk chunk sizes (the fixed generation lattice guarantees it)."""
    for fn, kw in ((stream_sbm_graph, dict(homophily=0.8)),
                   (stream_powerlaw_graph, dict(alpha=2.3))):
        stores = [fn(tmp_path / f"{fn.__name__}-{i}", n=2000, feat_dim=6,
                     avg_degree=4.0, seed=11, chunk_nodes=cn,
                     chunk_edges=ce, **kw)
                  for i, (cn, ce) in enumerate([(97, 389), (1024, 8192)])]
        a, b = (load_graph_store(s) for s in stores)
        for f in ("indptr", "indices", "features", "labels",
                  "train_mask", "val_mask", "test_mask"):
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f"{fn.__name__}.{f}")


def test_stream_sbm_is_homophilous(tmp_path):
    store = stream_sbm_graph(tmp_path / "sbm", n=3000, n_classes=5,
                             feat_dim=4, avg_degree=8.0, homophily=0.9,
                             seed=2)
    g = load_graph_store(store)
    dst, src = g.edge_list()
    intra = float((g.labels[dst] == g.labels[src]).mean())
    # homophily 0.9 over 5 classes → inter edges rarely land intra
    assert intra > 0.75, intra
    assert g.num_edges > 3000 * 4          # roughly avg_degree


def test_stream_powerlaw_has_heavy_tail(tmp_path):
    store = stream_powerlaw_graph(tmp_path / "pl", n=5000, feat_dim=4,
                                  avg_degree=8.0, alpha=2.3, seed=3)
    deg = store.degrees().astype(np.float64)
    assert deg.max() > 12 * deg.mean(), (deg.max(), deg.mean())
    # top 1% of nodes carry an outsized share of the edges
    top = np.sort(deg)[-len(deg) // 100:]
    assert top.sum() > 0.10 * deg.sum()


# ---------------------------------------------------------------------------
# Multilevel partitioner quality
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multilevel_cut_within_bound_of_in_memory(tmp_path):
    """Forced out-of-core path (coarsen → initial → uncoarsen+refine)
    lands within 1.1× of the in-memory metis-like cut, balanced."""
    q, slack = 4, 1.05
    g = citation_graph(n=4000, seed=0)
    store = write_graph_store(g, tmp_path / "s", chunk_nodes=509,
                              chunk_edges=4093)
    owner = stream_partition(store, q, scheme="metis-like", seed=0,
                             slack=slack, in_core_nodes=0,
                             coarsen_target=500, refine_max_nodes=5000)
    cut = stream_edge_cut(store, owner)["cross_frac"]
    ref = edge_cut_stats(g, metis_like_partition(g, q, seed=0))
    assert cut <= 1.1 * ref["cross_frac"], (cut, ref["cross_frac"])
    sizes = np.bincount(owner, minlength=q)
    assert sizes.max() <= slack * g.num_nodes / q + 1


def test_stream_partition_exact_path_matches_in_memory(tmp_path):
    """Graphs that fit in ``in_core_nodes`` reduce exactly to the
    in-memory partitioner — same owner vector, both schemes."""
    from repro.graph.partition import PARTITIONERS
    g = tiny_graph(n=180, seed=9)
    store = write_graph_store(g, tmp_path / "s", chunk_nodes=23,
                              chunk_edges=131)
    for scheme in ("random", "metis-like"):
        np.testing.assert_array_equal(
            stream_partition(store, 3, scheme=scheme, seed=4),
            PARTITIONERS[scheme](g, 3, seed=4), err_msg=scheme)


# ---------------------------------------------------------------------------
# Shard loader
# ---------------------------------------------------------------------------


def _small_shards(tmp_path, q=3):
    g = tiny_graph(n=160, seed=6)
    store = write_graph_store(g, tmp_path / "s", chunk_nodes=19,
                              chunk_edges=101)
    owner = stream_partition(store, q, scheme="metis-like", seed=0)
    return write_shards(store, owner, tmp_path / "shards")


def test_shard_meta_reads_no_arrays(tmp_path):
    from repro.dist.halo import HaloSpec
    d = _small_shards(tmp_path)
    meta = shard_meta(d)
    assert isinstance(meta["halo_spec"], HaloSpec)
    for k in ("q", "part_size", "halo_size", "num_nodes", "num_edges",
              "halo_demand", "n_train", "n_val", "n_test"):
        assert isinstance(meta[k], int), k
    assert meta["q"] == 3


def test_load_shards_subset_slices_full_stack(tmp_path):
    d = _small_shards(tmp_path)
    full = load_shards(d)
    sub = load_shards(d, parts=[1])
    assert sub.parts == (1,)
    for k, v in sub.arrays.items():
        np.testing.assert_array_equal(v[0], full.arrays[k][1], err_msg=k)
    # global facts are identical regardless of which shard was read
    assert (sub.q, sub.part_size, sub.halo_size) == \
        (full.q, full.part_size, full.halo_size)
    assert sub.halo_spec == full.halo_spec


def test_shard_dir_files_are_per_partition(tmp_path):
    d = _small_shards(tmp_path, q=4)
    names = sorted(os.listdir(d))
    assert [n for n in names if n.startswith("part_")] == \
        [f"part_{p:05d}.npz" for p in range(4)]
    assert "shards.json" in names and "owner.npy" in names


def test_load_shards_parts_validation(tmp_path):
    """Subset loading rejects empty/duplicate/out-of-range part lists and
    names a missing partition file (ISSUE 8 satellite — the elastic-Q
    single-shard worker boot depends on precise errors here)."""
    import pytest
    d = _small_shards(tmp_path)
    with pytest.raises(ValueError, match="at least one"):
        load_shards(d, parts=[])
    with pytest.raises(ValueError, match="duplicate"):
        load_shards(d, parts=[0, 0])
    with pytest.raises(ValueError, match="out of range"):
        load_shards(d, parts=[0, 7])
    os.remove(os.path.join(d, "part_00001.npz"))
    with pytest.raises(FileNotFoundError, match="part_00001"):
        load_shards(d, parts=[1])
    # surviving shards still load individually
    assert load_shards(d, parts=[2]).parts == (2,)
