"""End-to-end behaviour tests for the VARCO system (deliverable c).

Mirrors the paper's claims on a scaled-down problem: Algorithm 1 end to
end, the ledger's accuracy-per-byte dominance, and the transformer-side
VARCO integration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FULL_COMM, NO_COMM, CommPolicy, varco
from repro.graph import citation_graph, tiny_graph
from repro.train import train_gnn


def test_algorithm1_end_to_end_varco_run():
    """Algorithm 1: partition -> compressed train loop -> converged model."""
    g = tiny_graph(n=512, seed=0)
    res = train_gnn(g, q=4, scheme="metis-like",
                    policy=varco(60, slope=5), epochs=60, eval_every=20,
                    hidden=32)
    h = res.history
    # learned something
    assert h.final_test_acc > 0.5
    # rate annealed 128 -> 1
    assert h.rate[0] > 100 and h.rate[-1] == 1.0
    # communication accumulated monotonically, cheaper early
    assert all(b2 >= b1 for b1, b2 in zip(h.halo_gfloats, h.halo_gfloats[1:]))
    per_epoch_early = h.halo_gfloats[1] / max(h.epoch[1], 1)
    per_epoch_late = (h.halo_gfloats[-1] - h.halo_gfloats[-2]) / \
        (h.epoch[-1] - h.epoch[-2])
    assert per_epoch_late > 2 * per_epoch_early


def test_accuracy_per_byte_dominance():
    """Fig. 5's claim: at matched byte budgets VARCO >= full-comm accuracy."""
    g = citation_graph(n=2000, seed=4)
    kw = dict(q=4, scheme="random", epochs=100, eval_every=10, hidden=32,
              seed=0)
    full = train_gnn(g, policy=FULL_COMM, **kw).history
    var = train_gnn(g, policy=varco(100, slope=5), **kw).history

    # sample matched byte budgets within both trajectories.  Low/mid budgets
    # are the regime the efficiency claim targets; at this unit-test scale
    # (2k nodes) the compressed early phase costs some final accuracy —
    # full-curve dominance is exercised at benchmark scale in
    # benchmarks/fig3_fig5_accuracy.py.
    budgets = np.linspace(0.02, 0.45, 8) * min(full.halo_gfloats[-1],
                                               var.halo_gfloats[-1])

    def acc_at(h, budget):
        idx = np.searchsorted(h.halo_gfloats, budget)
        idx = min(idx, len(h.test_acc) - 1)
        return h.test_acc[idx]

    wins = sum(acc_at(var, b) >= acc_at(full, b) - 0.02 for b in budgets)
    assert wins >= 6, [(acc_at(var, b), acc_at(full, b)) for b in budgets]


def test_transformer_varco_grad_compression_trains():
    """The paper's technique on an assigned arch: VARCO-compressed
    data-parallel gradients still reduce the LM loss (single-device mesh)."""
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.dist.grad_compress import make_varco_dp_train_step
    from repro.launch.steps import make_optimizer
    from repro.models.transformer import init_lm

    cfg = get_config("granite-3-2b", smoke=True)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    params = init_lm(jax.random.key(0), cfg)
    opt = make_optimizer(cfg, lr=3e-3)
    pol = varco(20, slope=5, c_max=8.0)
    step = make_varco_dp_train_step(cfg, opt, pol, mesh)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32)
    s = opt.init(params)
    losses = []
    p = params
    for i in range(8):
        p, s, m = step(p, s, {"tokens": toks}, jnp.asarray(i),
                       jax.random.key(i))
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]
    assert float(m["rate"]) < 8.0          # scheduler annealing
    assert float(m["grad_bits"]) >= 0.0
