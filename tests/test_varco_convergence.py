"""Convergence behaviour (paper Prop. 1 / Prop. 2, qualitatively).

Small problems, short budgets — the full 300-epoch sweeps live in
benchmarks/; these tests assert the *ordering* the theory predicts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FULL_COMM, NO_COMM, fixed, varco
from repro.dist.gnn_parallel import DistMeta, make_train_step
from repro.graph import citation_graph, partition_graph, tiny_graph
from repro.nn import GNNConfig, init_gnn
from repro.train import train_gnn
from repro.train.optim import adamw, global_norm


@pytest.fixture(scope="module")
def trained():
    g = citation_graph(n=1500, seed=3)
    out = {}
    for name, pol in [("full", FULL_COMM), ("none", NO_COMM),
                      ("fixed64", fixed(64.0)),
                      ("varco", varco(80, slope=5))]:
        out[name] = train_gnn(g, q=4, scheme="random", policy=pol,
                              epochs=80, eval_every=40, hidden=32,
                              lr=5e-3, seed=0)
    return out


def test_ordering_full_vs_none(trained):
    """Communication must matter: full-comm beats no-comm."""
    assert trained["full"].history.final_test_acc > \
        trained["none"].history.final_test_acc + 0.05


def test_varco_close_to_full(trained):
    """Prop. 2: variable compression recovers (near) full-comm accuracy."""
    assert trained["varco"].history.final_test_acc > \
        trained["full"].history.final_test_acc - 0.06


def test_varco_beats_heavy_fixed(trained):
    """Prop. 1 vs 2: a heavily fixed-compressed run converges to a worse
    neighbourhood than the annealed schedule."""
    assert trained["varco"].history.final_test_acc >= \
        trained["fixed64"].history.final_test_acc - 0.01


def test_varco_cheaper_than_full(trained):
    assert trained["varco"].history.total_halo_gfloats < \
        0.9 * trained["full"].history.total_halo_gfloats


def test_fixed_compression_gradient_neighborhood():
    """Prop. 1: the stationary gradient-norm plateau grows with ε(r)."""
    g = tiny_graph(n=256, seed=1)
    cfg = GNNConfig(conv="sage", in_dim=g.feat_dim, hidden=16,
                    out_dim=g.num_classes, layers=2)
    pg = partition_graph(g, 4, scheme="random")
    graph = pg.device_arrays()

    def final_grad_norm(rate: float, epochs: int = 120) -> float:
        params = init_gnn(jax.random.key(0), cfg)
        meta = DistMeta.build(pg, params)
        opt = adamw(5e-3)
        s = opt.init(params)
        pol = FULL_COMM if rate == 1.0 else fixed(rate)
        step = make_train_step(cfg, pol, opt, meta)
        p = params
        for i in range(epochs):
            p, s, m = step(p, s, graph, jnp.asarray(i), jax.random.key(i))
        # measure the *full-communication* gradient at the found params —
        # the quantity Prop. 1 bounds
        full_step = make_train_step(cfg, FULL_COMM, opt, meta)
        from repro.dist.gnn_parallel import (_local_loss_fn,
                                             _make_aggregate_emulated)
        agg = _make_aggregate_emulated(graph, meta, FULL_COMM, None,
                                       jnp.ones(()), jax.random.key(0))
        grads = jax.grad(lambda q: _local_loss_fn(
            q, cfg, graph, agg, meta, psum=False)[0])(p)
        return float(global_norm(grads))

    g1 = final_grad_norm(1.0)
    g64 = final_grad_norm(64.0)
    # heavily compressed training stalls farther from stationarity
    assert g64 > g1, (g64, g1)
