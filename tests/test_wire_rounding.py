"""Stochastic wire rounding (DESIGN.md §3.8): the TPU-default codec.

The ``rounding`` axis of the quantised halo wire: ``default_wire_rounding``
backend resolution, the ``rounding=None`` → ``"rint"`` golden-trace pin on
CPU, the ``quant_dequant(key=...)`` error bound and determinism, the
``round_key`` per-(sender, hop) stream separation, and the slow
cross-backend parity pin of the stochastic wire + shard error feedback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from parity import build_setup, run_ef_parity

from repro.core import CommPolicy
from repro.dist.gnn_parallel import DistMeta
from repro.dist.ratectl import RatePlan, init_wire_residuals, \
    make_auto_train_step
from repro.kernels.ops import LANE, default_wire_rounding, quant_dequant, \
    round_key
from repro.train.optim import sgd

Q = 4
F = 256
NB = F // LANE


# ---------------------------------------------------------------------------
# backend resolution + golden-trace pin
# ---------------------------------------------------------------------------


def test_default_wire_rounding_backend():
    """CPU (and any non-TPU backend) defaults to the deterministic
    parity-checked codec; only TPU opts into stochastic rounding."""
    expect = "stochastic" if jax.default_backend() == "tpu" else "rint"
    assert default_wire_rounding() == expect


@pytest.mark.skipif(jax.default_backend() == "tpu",
                    reason="rounding=None resolves to stochastic on TPU")
def test_rounding_none_is_rint_bitwise_on_cpu():
    """``make_auto_train_step(rounding=None)`` must reproduce the
    explicit ``"rint"`` step bit-for-bit on CPU — every pre-existing
    golden trace was recorded under the deterministic codec."""
    _, cfg, params, pg, graph = build_setup(Q, f=F, layers=2, n=192)
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:budget:1e9:w4", 4)
    opt = sgd(1e-2)
    rm = np.full((Q, Q), 2.0, np.float32)
    np.fill_diagonal(rm, 1.0)
    wm = np.full((Q, Q), 4.0, np.float32)
    np.fill_diagonal(wm, 32.0)
    plan = RatePlan(jnp.asarray(rm), jnp.zeros((Q, Q), jnp.float32),
                    jnp.asarray(wm))
    outs = []
    for rounding in (None, "rint"):
        step = make_auto_train_step(cfg, policy, opt, meta,
                                    rounding=rounding)
        p, s = params, opt.init(params)
        cache = init_wire_residuals(meta, cfg)
        p, s, m, cache = step(p, s, graph, jax.random.key(7), plan, cache)
        outs.append((p, m, cache))
    (p0, m0, c0), (p1, m1, c1) = outs
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(c0, c1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m0["transport_bits"]) == float(m1["transport_bits"])


def test_make_auto_train_step_rejects_unknown_rounding():
    _, cfg, params, pg, _ = build_setup(Q, f=F, layers=2, n=192)
    meta = DistMeta.build(pg, params, wire="p2p")
    policy = CommPolicy.parse("auto:budget:1e9:w4", 4)
    with pytest.raises(ValueError, match="rounding"):
        make_auto_train_step(cfg, policy, sgd(1e-2), meta,
                             rounding="nearest-even")


# ---------------------------------------------------------------------------
# quant_dequant stochastic mode
# ---------------------------------------------------------------------------


def test_quant_dequant_stochastic_error_bound():
    """Stochastic rounding stays within one quantisation step of the
    input per element: |x - dq| ≤ amax_block / (2^(w-1) - 1)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (32, F)) * \
        10.0 ** jax.random.uniform(jax.random.fold_in(key, 1), (32, 1),
                                   minval=-2.0, maxval=2.0)
    for width in (2.0, 4.0, 8.0):
        dq = quant_dequant(x, width, key=jax.random.fold_in(key, 2))
        amax = jnp.max(jnp.abs(x.reshape(32, NB, LANE)), axis=-1)
        step = amax / (2.0 ** (width - 1.0) - 1.0)
        err = jnp.abs(x - dq).reshape(32, NB, LANE)
        assert float(jnp.max(err - step[..., None])) <= 1e-6


def test_quant_dequant_stochastic_deterministic_per_key():
    key = jax.random.key(3)
    x = jax.random.normal(key, (8, F))
    a = quant_dequant(x, 4.0, key=jax.random.fold_in(key, 1))
    b = quant_dequant(x, 4.0, key=jax.random.fold_in(key, 1))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = quant_dequant(x, 4.0, key=jax.random.fold_in(key, 2))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    # and it genuinely differs from round-to-nearest somewhere
    r = quant_dequant(x, 4.0)
    assert not np.array_equal(np.asarray(a), np.asarray(r))


def test_quant_dequant_stochastic_unbiased():
    """floor(v + u) is unbiased: averaging many independent stochastic
    quantisations converges to the input, while rint stays put."""
    key = jax.random.key(5)
    x = jax.random.normal(key, (4, F))
    acc = jnp.zeros_like(x)
    trials = 256
    for t in range(trials):
        acc = acc + quant_dequant(x, 3.0, key=jax.random.fold_in(key, t))
    mean = acc / trials
    amax = jnp.max(jnp.abs(x.reshape(4, NB, LANE)), axis=-1)
    step = float(jnp.max(amax)) / (2.0 ** 2.0 - 1.0)
    # mean error an order below one quantisation step
    assert float(jnp.max(jnp.abs(mean - x))) < 0.25 * step


def test_quant_dequant_stochastic_width32_passthrough():
    key = jax.random.key(9)
    x = jax.random.normal(key, (8, F))
    dq = quant_dequant(x, 32.0, key=jax.random.fold_in(key, 1))
    np.testing.assert_array_equal(np.asarray(dq), np.asarray(x))


# ---------------------------------------------------------------------------
# key schedule
# ---------------------------------------------------------------------------


def test_round_key_distinct_streams_per_sender_and_hop():
    """Every (sender, hop) pair must draw its own uniform stream — and
    the salted chain must not collide with the raw exchange key that
    feeds the mask-selection draws."""
    base = jax.random.key(11)
    keys = [round_key(base, s, d) for s in range(Q) for d in range(Q - 1)]
    keys += [round_key(base, s) for s in range(Q)]
    keys.append(base)
    data = np.stack([np.asarray(jax.random.key_data(k)) for k in keys])
    flat = {tuple(row.ravel().tolist()) for row in data}
    assert len(flat) == len(keys)
    # hop=None matches no hop-indexed key; draws differ stream-to-stream
    u = np.stack([np.asarray(jax.random.uniform(k, (4,))) for k in keys])
    assert len({tuple(r.tolist()) for r in u}) == len(keys)


# ---------------------------------------------------------------------------
# cross-backend parity (slow): stochastic wire + shard error feedback
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ef_parity_both_roundings_subprocess():
    """S2+S3 acceptance pin: the emulated and shard_map backends agree to
    ≤ 1e-6 on params and EF residuals after quantised training steps,
    under BOTH the deterministic and the stochastic wire codec (the
    (seed, step, pair) key schedule makes the streams identical)."""
    run_ef_parity(4, roundings=("rint", "stochastic"))
